"""Paper §4 (async): engine-driven EASGD/ASGD vs BSP — tau sweep.

The paper reports 42% lower async comm overhead than Platoon at tau=1 and
a grid search over (alpha, tau). Here, everything goes through the unified
engine (one ``TrainPlan`` per row): per-step wall time of the async plans
at several tau vs the BSP/ASA step, with the **center exchange on the
shared exchanger layer at fp16 wire** (``asa16``) — the elastic traffic
gets the same ASA decomposition + wire precision as BSP gradients. tau is
structural (local steps compile without any param-sized collective), so
the sweep measures real comm amortization, not a masked collective.
"""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
QUICK = %(quick)d
import json, time
import jax, numpy as np
from repro.configs import get_smoke_config
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum
from repro.train.engine import TrainPlan, build_engine

cfg = get_smoke_config("llama3.2-1b").with_overrides(vocab_size=128)
model = build_model(cfg)
opt = sgd_momentum(weight_decay=0.0)
mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
src = LMTokenSource(cfg.vocab_size, 32)
B = 32
steps = 4 if QUICK else 8
rows = []

def timeit(plan, lr=0.02):
    eng = build_engine(plan, model, opt, constant(lr), mesh)
    state = eng.init_state(jax.random.key(0))
    # warm both programs (local + sync) before timing
    _ = eng.step(state, src.batch(B, 0), jax.random.key(0), step_idx=0)
    if plan.tau > 1:
        _ = eng.step(state, src.batch(B, 0), jax.random.key(0),
                     step_idx=plan.tau - 1)
    jax.block_until_ready(_[0])
    # losses stay on device inside the timed region: a per-step float()
    # would serialize dispatch and charge a host round-trip to every row
    losses = []
    t0 = time.perf_counter()
    for i in range(steps):
        state, m = eng.step(state, src.batch(B, i), jax.random.key(i),
                            step_idx=i)
        losses.append(m["loss"])
    jax.block_until_ready(state)
    dt = time.perf_counter() - t0
    return dt / steps * 1e6, [float(l) for l in losses]

us, losses = timeit(TrainPlan(algo="bsp", exchanger="asa"))
rows.append({"name": "bsp_asa", "us": us, "final_loss": losses[-1]})
base = us

# NOTE: on shared-host CPU devices the 8 virtual workers timeshare, so
# wall overhead mostly reflects elastic-update math, not network cost;
# wire bytes per tau are the derived column that transfers to real links.
taus = [1, 4] if QUICK else [1, 2, 4]
for tau in taus:
    plan = TrainPlan(algo="easgd", exchanger="asa16", tau=tau, alpha=0.5)
    us, losses = timeit(plan)
    rows.append({"name": f"easgd_asa16_tau{tau}_a0.5", "us": us,
                 "final_loss": losses[-1],
                 "overhead_vs_bsp": us / base - 1.0,
                 "wire": f"fp16;center_exch_per_{tau}_steps"})
# asgd applies the SUM of worker deltas -> lr scales down by k (like
# awagd's lr-scales-with-k, see DESIGN.md)
us, losses = timeit(TrainPlan(algo="asgd", exchanger="asa16", tau=2),
                    lr=0.02 / 8)
rows.append({"name": "asgd_asa16_tau2", "us": us, "final_loss": losses[-1],
             "overhead_vs_bsp": us / base - 1.0,
             "wire": "fp16;center_exch_per_2_steps"})
print("RESULTS_JSON:" + json.dumps(rows))
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT % {"quick": int(quick)}
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    rows = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            rows = json.loads(line[len("RESULTS_JSON:"):])
    out = []
    for r in rows:
        derived = f"final_loss={r['final_loss']:.3f}"
        if "overhead_vs_bsp" in r:
            derived += f";overhead_vs_bsp={r['overhead_vs_bsp']:+.1%}"
        if "wire" in r:
            derived += f";{r['wire']}"
        out.append((f"easgd/{r['name']}", r["us"], derived))
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
