"""Paper §4 (async): EASGD vs BSP per-step overhead and tau sweep.

The paper reports 42% lower async comm overhead than Platoon at tau=1 and a
grid search over (alpha, tau). Here: per-step wall time of EASGD at several
tau vs the BSP/ASA step, plus final-loss comparison on the synthetic LM.
"""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, numpy as np
from repro.configs import get_smoke_config
from repro.core import (get_exchanger, init_easgd_state, init_train_state,
                        make_bsp_step, make_easgd_step)
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum

cfg = get_smoke_config("llama3.2-1b").with_overrides(vocab_size=128)
model = build_model(cfg)
opt = sgd_momentum(weight_decay=0.0)
mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
src = LMTokenSource(cfg.vocab_size, 32)
B = 32
rows = []

def timeit(fn, state, steps=6):
    losses = []
    state, m = fn(state, src.batch(B, 0), jax.random.key(0))
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    for i in range(steps):
        state, m = fn(state, src.batch(B, i), jax.random.key(i))
        losses.append(float(m["loss"]))
    jax.block_until_ready(m)
    return (time.perf_counter() - t0) / steps * 1e6, losses

bsp = jax.jit(make_bsp_step(model, opt, get_exchanger("asa"),
                            constant(0.02), mesh))
us, losses = timeit(bsp, init_train_state(model, opt, jax.random.key(0)))
rows.append({"name": "bsp_asa", "us": us, "final_loss": losses[-1]})
base = us

for tau in [1, 2, 4]:
    for alpha in [0.5]:
        estep = jax.jit(make_easgd_step(model, constant(0.02), mesh,
                                        alpha=alpha, tau=tau))
        st = init_easgd_state(model, opt, jax.random.key(0), 8)
        us, losses = timeit(estep, st)
                # NOTE: on this 1-core host all 8 virtual workers timeshare, so
        # wall overhead mostly reflects the extra elastic-update math, not
        # network cost; wire bytes are in EXPERIMENTS.md.
        rows.append({"name": f"easgd_tau{tau}_a{alpha}", "us": us,
                     "final_loss": losses[-1],
                     "overhead_vs_bsp": us / base - 1.0})
print("RESULTS_JSON:" + json.dumps(rows))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    rows = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            rows = json.loads(line[len("RESULTS_JSON:"):])
    out = []
    for r in rows:
        derived = f"final_loss={r['final_loss']:.3f}"
        if "overhead_vs_bsp" in r:
            derived += f";overhead_vs_bsp={r['overhead_vs_bsp']:+.1%}"
        out.append((f"easgd/{r['name']}", r["us"], derived))
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
