"""Fault-tolerance overhead: the elastic harness vs the fixed engine.

Three questions, all on 8 virtual CPU workers:

- what does the elastic loop cost when nothing fails? (``elastic_clean``
  vs the fixed-engine easgd row: same algo/tau, but per-step membership
  bookkeeping + the weighted quorum sync program). Elastic per-step cost
  uses the two-length diff method — (T(long) - T(short)) / extra steps —
  so the program-build/compile cost cancels instead of polluting the row;
- what does one kill cost at the round boundary? (``rebuild_on_kill``:
  re-jit the programs for k-1 on a fresh mesh + reshard replica rows —
  read from the loop's own ``fault/rebuild`` telemetry span);
- what does an averaging round cost vs a local step? (``sync_round``:
  the ``fault/round`` span vs the amortized per-step cost; below-quorum
  rounds degrade to the local path, so this brackets the skip savings).

The wall numbers are CPU-host timings (workers timeshare the host); the
derived columns — overhead %%, rebuild latency, round/step ratio — are
the transferable shape.
"""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
QUICK = %(quick)d
import json, time
import jax, numpy as np
from repro import telemetry
from repro.telemetry import trace
from repro.configs import get_smoke_config
from repro.data.synthetic import LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum
from repro.train.engine import TrainPlan, build_engine
from repro.fault.elastic import elastic_train

cfg = get_smoke_config("llama3.2-1b").with_overrides(vocab_size=128)
model = build_model(cfg)
opt = sgd_momentum(weight_decay=0.0)
src = LMTokenSource(cfg.vocab_size, 32)
batch_fn = lambda step, k: src.batch(4 * k, step)
tau = 4
short, long = (2 * tau, 6 * tau) if QUICK else (4 * tau, 12 * tau)
rows = []

# fixed-engine reference: same algo/tau, warmed, no membership machinery
mesh = jax.make_mesh((8,), ("data",))
jax.set_mesh(mesh)
plan_f = TrainPlan(algo="easgd", exchanger="ar", tau=tau, alpha=0.5)
eng = build_engine(plan_f, model, opt, constant(0.02), mesh)
state = eng.init_state(jax.random.key(0))
_ = eng.step(state, batch_fn(0, 8), jax.random.key(0), step_idx=0)
_ = eng.step(state, batch_fn(0, 8), jax.random.key(0), step_idx=tau - 1)
jax.block_until_ready(_[0])
n = long - short
t0 = time.perf_counter()
for i in range(n):
    state, m = eng.step(state, batch_fn(i, 8), jax.random.key(i),
                        step_idx=i)
jax.block_until_ready(state)
base = (time.perf_counter() - t0) / n * 1e6
rows.append({"name": "fixed_easgd_tau4", "us": base})

plan = TrainPlan(algo="easgd", exchanger="ar", tau=tau, alpha=0.5,
                 quorum=2)

def wall(num_steps, fault_plan=None):
    t0 = time.perf_counter()
    _, rep = elastic_train(model, opt, constant(0.02), batch_fn,
                           plan=plan, num_workers=8, num_steps=num_steps,
                           fault_plan=fault_plan, print_fn=None)
    return time.perf_counter() - t0, rep

# steady elastic per-step cost: build/compile cancels in the difference
t_short, _ = wall(short)
t_long, _ = wall(long)
us = (t_long - t_short) / (long - short) * 1e6
rows.append({"name": "elastic_clean_tau4", "us": us,
             "overhead_vs_fixed": us / base - 1.0})

# one kill: rebuild+reshard latency from the loop's own telemetry spans
telemetry.set_enabled(True)
trace.reset()
_, rep = wall(long, fault_plan="kill:7@%%d" %% (tau + 1))
spans = {name: dur for kind, name, t0_, dur, tid, attrs in trace.events()
         if kind == "X"}
telemetry.set_enabled(False)
assert rep.rebuilds == 1, rep
rows.append({"name": "rebuild_on_kill", "us": spans["fault/rebuild"] * 1e6,
             "reshard_us": spans["fault/reshard"] * 1e6,
             "note": "k=8->7 re-jit + row reshard at one round boundary"})

# a synced averaging round vs the amortized step: the fault/round span
telemetry.set_enabled(True)
trace.reset()
wall(long)
round_durs = [dur for kind, name, t0_, dur, tid, attrs in trace.events()
              if kind == "X" and name == "fault/round"]
telemetry.set_enabled(False)
round_us = float(np.median(round_durs)) * 1e6
rows.append({"name": "sync_round_dispatch", "us": round_us,
             "round_over_step": round_us / us,
             "note": "host-side dispatch window of the quorum sync "
                     "(async dispatch; below-quorum rounds take the "
                     "local path instead)"})
print("RESULTS_JSON:" + json.dumps(rows))
"""


def run(quick: bool = False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT % {"quick": int(quick)}
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    rows = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            rows = json.loads(line[len("RESULTS_JSON:"):])
    out = []
    for r in rows:
        derived = []
        if "overhead_vs_fixed" in r:
            derived.append(f"overhead_vs_fixed={r['overhead_vs_fixed']:+.1%}")
        if "reshard_us" in r:
            derived.append(f"reshard_us={r['reshard_us']:.0f}")
        if "round_over_step" in r:
            derived.append(f"round_over_step={r['round_over_step']:.2f}x")
        if "note" in r:
            derived.append(r["note"])
        out.append((f"fault/{r['name']}", r["us"], ";".join(derived)))
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
