"""Table 1: data-throughput speedup vs number of workers.

Trains the reduced AlexNet (paper's main model) with a fixed per-worker
batch on k = 1, 2, 4, 8 host devices and reports examples/s and speedup
vs k=1 (the paper reports 6.7x at 8 GPUs for AlexNet-128b).
"""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, numpy as np
from repro.configs import get_smoke_config
from repro.core import get_exchanger, init_train_state, make_bsp_step
from repro.data.synthetic import ImageSource, LMTokenSource
from repro.models import build_model
from repro.optim import constant, sgd_momentum

rows = []
for arch in ["alexnet", "llama3.2-1b"]:
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    opt = sgd_momentum(weight_decay=0.0)
    per_worker = 8
    base = None
    for k in [1, 2, 4, 8]:
        mesh = jax.make_mesh((k,), ("data",),
                             devices=np.array(jax.devices()[:k]))
        jax.set_mesh(mesh)
        step = jax.jit(make_bsp_step(model, opt, get_exchanger("asa"),
                                     constant(0.01), mesh))
        state = init_train_state(model, opt, jax.random.key(0))
        B = per_worker * k
        if cfg.family == "conv":
            src = ImageSource(cfg.image_size, cfg.num_classes)
            batch = src.batch(B, 0)
        else:
            src = LMTokenSource(cfg.vocab_size, 64)
            batch = src.batch(B, 0)
        state, _ = step(state, batch, jax.random.key(1))  # compile
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        reps = 3
        for i in range(reps):
            state, _ = step(state, batch, jax.random.key(i))
        jax.block_until_ready(state)
        dt = (time.perf_counter() - t0) / reps
        eps = B / dt
        if k == 1:
            base = eps
            base_dt = dt
        # this host has ONE core: k virtual workers timeshare it, so ideal
        # wall time is k*dt_1 (serialized compute). efficiency_vs_serial
        # isolates the parallelization (comm+sync) overhead the paper's
        # Table 1 measures on real parallel hardware.
        rows.append({"arch": arch, "k": k, "us_per_step": dt * 1e6,
                     "examples_per_s": eps, "speedup": eps / base,
                     "efficiency_vs_serial": (k * base_dt) / dt})
print("RESULTS_JSON:" + json.dumps(rows))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    rows = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULTS_JSON:"):
            rows = json.loads(line[len("RESULTS_JSON:"):])
    out = []
    for r in rows:
        out.append((f"scaling/{r['arch']}/k={r['k']}",
                    r["us_per_step"],
                    f"examples_per_s={r['examples_per_s']:.1f};"
                    f"speedup={r['speedup']:.2f};"
                    f"efficiency_vs_serial={r['efficiency_vs_serial']:.2f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
