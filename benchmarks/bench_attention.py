"""Attention hot-path bench: Pallas flash kernels vs the XLA einsum dense
path vs the blockwise scan, at S in {512, 2048, 8192} (quick: {512, 2048}),
fwd and fwd+bwd.

Per row: wall time -> tok/s, compiled peak workspace bytes
(``memory_analysis().temp_size_in_bytes`` — the dense path's (S,S) score
buffers live here), the modeled windowed-attention roofline
(``roofline.analysis.attention_flops_bytes``: FLOPs, minimal HBM bytes,
achieved-vs-peak fraction), and for the flash path the no-(S,S)-in-HLO
guard. On CPU the flash kernels run through the Pallas interpreter
(correctness-path timing, as in bench_kernels); compiled speed needs TPU.
The roofline + peak-memory columns are backend-independent evidence.
"""
import re
import time


def _bench(fn, args, S, reps=2):
    """One AOT compile per row: the compiled executable is what gets
    timed AND inspected (peak workspace + (S,S)-shape scan), so the
    measured computation and the evidence are the same HLO."""
    import jax
    c = jax.jit(fn).lower(*args).compile()
    out = c(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = c(*args)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / reps * 1e6
    ma = c.memory_analysis()
    temp = int(getattr(ma, "temp_size_in_bytes", 0))
    sxs = len(re.findall(rf"\[(?:\d+,)*{S},{S}\]", c.as_text()))
    return us, temp, sxs


def run(quick=False):
    import jax
    import jax.numpy as jnp

    from repro.configs.base import AttentionConfig
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import (causal_window_mask, gqa_attend,
                                        gqa_attend_blockwise)
    from repro.roofline.analysis import PEAK_FLOPS, attention_flops_bytes

    B, H, KV, hd = 1, 4, 2, 64
    a = AttentionConfig(num_heads=H, num_kv_heads=KV, head_dim=hd)
    seqs = [512, 2048] if quick else [512, 2048, 8192]
    rows = []
    for S in seqs:
        key = jax.random.key(S)
        q = jax.random.normal(key, (B, S, H, hd), jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd),
                              jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd),
                              jnp.bfloat16)
        pos = jnp.arange(S)
        keep = causal_window_mask(pos, pos, 0)
        bq = bk = min(512, max(128, S // 16))

        def dense(q, k, v):
            return gqa_attend(q, k, v, keep, a)

        def blockwise(q, k, v):
            return gqa_attend_blockwise(q, k, v, pos, pos, 0, a, block=512)

        def flash(q, k, v, window=0):
            return flash_attention(q, k, v, window=window, block_q=bq,
                                   block_k=bk)

        impls = [("dense", dense), ("blockwise", blockwise),
                 ("flash", flash)]

        def bwd_of(f):
            # grad wrt all of (q, k, v): dropping k/v would let XLA DCE
            # the dkv backward (kernel or einsum) out of the measurement
            def step(q, k, v):
                return jax.grad(
                    lambda q, k, v: f(q, k, v).astype(jnp.float32).sum(),
                    argnums=(0, 1, 2))(q, k, v)
            return step

        times = {}
        for kind in ("fwd", "fwd+bwd"):
            # quick mode trims the expensive half of the matrix: the big-S
            # backward columns (full mode runs everything)
            if quick and S >= 2048 and kind == "fwd+bwd":
                rows.append((f"attention/skipped_S{S}_{kind}", 0,
                             "quick=1;run_full_bench_for_this_row"))
                continue
            rf = attention_flops_bytes(
                batch=B, q_len=S, kv_len=S, heads=H, kv_heads=KV,
                head_dim_k=hd, kind=kind)
            for name, f in impls:
                us, temp, sxs = _bench(
                    f if kind == "fwd" else bwd_of(f), (q, k, v), S,
                    reps=1 if S >= 2048 else 2)
                times[(name, kind)] = us
                frac = rf["flops"] / (us * 1e-6) / PEAK_FLOPS
                rows.append((
                    f"attention/{name}_S{S}_{kind}", us,
                    f"tok_s={B * S / (us * 1e-6):.0f};"
                    f"peak_ws_mb={temp / 2 ** 20:.1f};"
                    f"model_gflop={rf['flops'] / 1e9:.2f};"
                    f"ai={rf['intensity']:.0f};"
                    f"roofline_frac={frac:.3g};sxs_shapes={sxs}"))
            d, fl = times[("dense", kind)], times[("flash", kind)]
            rows.append((f"attention/flash_over_dense_S{S}_{kind}", fl,
                         f"ratio={fl / d:.2f};dense_us={d:.0f}"))
        # windowed attention: the roofline goes linear in S and the kernel
        # skips out-of-window tiles
        rfw = attention_flops_bytes(batch=B, q_len=S, kv_len=S, heads=H,
                                    kv_heads=KV, head_dim_k=hd, window=256)
        us, _, _ = _bench(lambda q, k, v: flash(q, k, v, window=256),
                          (q, k, v), S, reps=1 if S >= 2048 else 2)
        rows.append((f"attention/flash_w256_S{S}_fwd", us,
                     f"tok_s={B * S / (us * 1e-6):.0f};"
                     f"model_gflop={rfw['flops'] / 1e9:.2f};"
                     f"pairs_frac={rfw['pairs'] / (S * (S + 1) // 2):.3f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick=True):
        print(f"{name},{us:.1f},{derived}")
