"""dist spec-construction micro-bench: ``param_spec``+``sanitize_spec`` and
the ``param_shardings``/``state_shardings`` builders over the LARGEST config
(mistral-large-123b, 88 stacked layers) on the production mesh shapes.

Spec construction runs once per compile, but the dry-run sweeps hundreds of
(arch x shape x mesh x mode) programs — it must stay off the hot path.
Derived: leaf count and per-leaf cost.
"""
import time


def _time(fn, reps=5):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    import jax
    from repro.configs import get_config
    from repro.dist.sharding import (param_spec, param_shardings,
                                     sanitize_spec, state_shardings)
    from repro.launch.specs import abstract_state
    from repro.models import build_model
    from repro.optim import sgd_momentum
    from repro.testing import FakeMesh

    cfg = get_config("mistral-large-123b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})

    rows = []

    def specs_all():
        for path, leaf in leaves:
            sanitize_spec(param_spec(path, leaf), leaf.shape, mesh)

    us = _time(specs_all)
    rows.append(("dist/param_spec+sanitize_123b", us,
                 f"leaves={len(leaves)};us_per_leaf={us / len(leaves):.1f}"))

    # full builders need a real (1-device) mesh for NamedSharding
    rmesh = jax.make_mesh((1, 1), ("data", "model"))
    us = _time(lambda: param_shardings(rmesh, params))
    rows.append(("dist/param_shardings_123b", us, f"leaves={len(leaves)}"))

    state = abstract_state(model, sgd_momentum(weight_decay=0.0))
    n_state = len(jax.tree.leaves(state))
    us = _time(lambda: state_shardings(rmesh, state))
    rows.append(("dist/state_shardings_123b", us, f"leaves={n_state}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
